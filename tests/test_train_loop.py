"""Training loop: convergence on learnable data, checkpoint/restart,
failure-injection recovery (DESIGN.md §5)."""

import os

import jax
import numpy as np

from repro import jaxcompat as compat
from repro.comms.faults import FaultPlan, StepCrash
from repro.configs.base import ArchConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import LM
from repro.optim import OptConfig, lr_schedules
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train import checkpoint as ckpt
from repro.train.step import StepConfig

TINY = ArchConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64, remat="none",
)


def _stream(batch=8, seq=32, vocab=64):
    return SyntheticStream(SyntheticConfig(
        vocab_size=vocab, seq_len=seq, global_batch=batch, kind="markov"))


def test_loss_decreases_on_markov_data(tmp_path):
    model = LM(TINY)
    opt = OptConfig(kind="adamw", lr=3e-3)
    step_cfg = StepConfig(mode="pjit")
    mesh = make_local_mesh()
    state = init_state(jax.random.PRNGKey(0), model, opt)
    stream = _stream()
    loop_cfg = TrainLoopConfig(total_steps=60, log_every=5,
                               lr_schedule=lr_schedules.constant())
    with compat.set_mesh(mesh):
        out = train_loop(model, opt, step_cfg, mesh, state, stream, loop_cfg)
    hist = out["history"]
    first, last = hist[0]["loss"], hist[-1]["loss"]
    floor = np.log(TINY.vocab_size)
    assert last < first - 0.3, f"no learning: {first:.3f} -> {last:.3f}"
    assert last < floor  # better than uniform guessing


def test_checkpoint_save_restore_exact(tmp_path):
    model = LM(TINY)
    opt = OptConfig(kind="adamw", lr=1e-3)
    state = init_state(jax.random.PRNGKey(1), model, opt)
    path = str(tmp_path / "ck")
    ckpt.save(path, 7, state)
    assert ckpt.latest_step(path) == 7
    restored, step = ckpt.restore(path, state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_resume_is_bit_exact(tmp_path):
    """Train 20 straight vs 10 + restart + 10: identical final params."""
    mesh = make_local_mesh()
    model = LM(TINY)
    opt = OptConfig(kind="adamw", lr=1e-3)
    stream = _stream()
    step_cfg = StepConfig(mode="pjit")

    def fresh_state():
        return init_state(jax.random.PRNGKey(2), model, opt)

    with compat.set_mesh(mesh):
        out_straight = train_loop(
            model, opt, step_cfg, mesh, fresh_state(), stream,
            TrainLoopConfig(total_steps=20, log_every=100))

        ck = str(tmp_path / "resume")
        train_loop(model, opt, step_cfg, mesh, fresh_state(), stream,
                   TrainLoopConfig(total_steps=10, ckpt_dir=ck, ckpt_every=10,
                                   log_every=100))
        out_resumed = train_loop(
            model, opt, step_cfg, mesh, fresh_state(), stream,
            TrainLoopConfig(total_steps=20, ckpt_dir=ck, ckpt_every=10,
                            log_every=100))

    a = jax.tree_util.tree_leaves(out_straight["state"]["params"])
    b = jax.tree_util.tree_leaves(out_resumed["state"]["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.array(x), np.array(y))


def test_failure_injection_rolls_back(tmp_path):
    """A step that blows up mid-run recovers from the last checkpoint and
    completes (fleet-scale requirement: node failure != job failure).  The
    crash is a typed FaultPlan event (DESIGN.md §19) — it fires exactly
    once, the loop rolls back to the newest checkpoint, and the retried
    run finishes."""
    mesh = make_local_mesh()
    model = LM(TINY)
    opt = OptConfig(kind="adamw", lr=1e-3)
    stream = _stream()
    plan = FaultPlan(events=(StepCrash(step=12),))

    loop_cfg = TrainLoopConfig(total_steps=16, ckpt_dir=str(tmp_path / "fi"),
                               ckpt_every=5, log_every=100, faults=plan)
    with compat.set_mesh(mesh):
        out = train_loop(
            model, opt, StepConfig(mode="pjit"), mesh,
            init_state(jax.random.PRNGKey(3), model, opt), stream, loop_cfg)
    assert int(out["state"]["step"]) == 16
    assert loop_cfg.fired_faults == {0}  # the crash fired exactly once


def test_checkpoint_gc_keeps_last_k(tmp_path):
    model = LM(TINY)
    opt = OptConfig(kind="sgd")
    state = init_state(jax.random.PRNGKey(4), model, opt)
    mgr = ckpt.CheckpointManager(str(tmp_path / "gc"), every=1, keep=2)
    for s in range(1, 6):
        mgr.maybe_save(s, state)
    kept = sorted(os.listdir(str(tmp_path / "gc")))
    assert kept == ["step_00000004", "step_00000005"]
