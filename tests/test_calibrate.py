"""Calibrated cost model (DESIGN.md §17): the α–β fit must recover known
constants from synthetic timings, the calibration artifact must round-trip
and reject stale keys, profile-threaded pricing must equal the static
defaults when uncalibrated, and the three mispriced-input bugfixes stay
fixed — the real worker count reaches the auto policy (a borderline
8-worker decision flips vs the old hardcoded P=2), the streamed timeline's
``exposed + hidden == exchange`` accounting identity holds everywhere, and
psum decisions price the dense runtime wire, not the sparse modeled
endpoint."""

import dataclasses
import json

import pytest

from helpers import given, settings, st, run_with_devices

from repro.comms import bucketing, calibrate, cost_model as cm, scheduler
from repro.comms.calibrate import (
    CostProfile,
    LinkFit,
    ProfileKey,
    ProfileKeyMismatch,
    UNCALIBRATED,
    fit_alpha_beta,
)
from repro.comms.reducers import ReducerConfig, make_reducer


# ---------------------------------------------------------------------------
# α–β fit
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=30)
@given(
    alpha_us=st.floats(1.0, 500.0),
    gbps=st.floats(0.1, 400.0),
)
def test_fit_recovers_known_alpha_beta(alpha_us, gbps):
    """Noiseless timings generated from a known linear model fit back to it."""
    alpha = alpha_us * 1e-6
    beta = 1.0 / (gbps * 1e9)
    sizes = [float(1 << p) for p in range(16, 25, 2)]
    times = [alpha + beta * b for b in sizes]
    a, b = fit_alpha_beta(sizes, times)
    assert a == pytest.approx(alpha, rel=1e-6)
    assert b == pytest.approx(beta, rel=1e-6)


def test_fit_floors_degenerate_sweeps():
    # single distinct size (zero variance): alpha = mean time, beta floored
    a, b = fit_alpha_beta([0.0, 0.0, 0.0], [1e-4, 2e-4, 3e-4])
    assert a == pytest.approx(2e-4)
    assert b == calibrate.BETA_FLOOR_S_PER_BYTE
    # noisy negative intercept clamps to the alpha floor, never <= 0
    a, b = fit_alpha_beta([1e6, 2e6], [1e-4, 3e-4])
    assert a >= calibrate.ALPHA_FLOOR_S
    assert b > 0
    with pytest.raises(ValueError):
        fit_alpha_beta([], [])
    with pytest.raises(ValueError):
        fit_alpha_beta([1.0], [1.0, 2.0])


# ---------------------------------------------------------------------------
# artifact persistence
# ---------------------------------------------------------------------------


def _profile(model="m/100", platform="cpu", jax_version="0.0.0"):
    return CostProfile(
        key=ProfileKey(platform=platform, mesh=(("data", 4),),
                       model=model, jax_version=jax_version),
        fits=(LinkFit("gather", 25e-6, 1e-10, n_points=5),
              LinkFit("psum", 12e-6, 2e-10, n_points=5)),
        throughputs=cm.TPU_V5E,
        backprop_flops_per_s=3.2e12,
    )


def test_artifact_roundtrip(tmp_path):
    path = str(tmp_path / "cal.json")
    prof = _profile()
    prof.save(path)
    loaded = CostProfile.load(path, expect=prof.key)
    assert loaded == prof
    # numeric accessors survive the trip
    assert loaded.alpha_s("sequenced") == prof.alpha_s("sequenced")
    assert loaded.t_comm("psum") == pytest.approx(1.0 / 2e-10)
    assert loaded.backprop_s(100, 10) == pytest.approx(4.0 * 1000 / 3.2e12)


def test_stale_key_rejected(tmp_path):
    path = str(tmp_path / "cal.json")
    _profile().save(path)
    other = ProfileKey(platform="tpu", mesh=(("data", 4),),
                       model="m/100", jax_version="0.0.0")
    with pytest.raises(ProfileKeyMismatch):
        CostProfile.load(path, expect=other)
    # strict=False downgrades the mismatch to acceptance
    assert CostProfile.load(path, expect=other, strict=False).key.platform == "cpu"


def test_unknown_artifact_version_rejected(tmp_path):
    path = str(tmp_path / "cal.json")
    d = _profile().to_dict()
    d["version"] = 999
    with open(path, "w") as f:
        json.dump(d, f)
    with pytest.raises(ProfileKeyMismatch):
        CostProfile.load(path)


def test_profile_validation():
    with pytest.raises(ValueError):  # missing psum family
        dataclasses.replace(_profile(), fits=(LinkFit("gather", 1e-6, 1e-10),))
    with pytest.raises(ValueError):  # non-positive alpha
        LinkFit("gather", 0.0, 1e-10)
    with pytest.raises(ValueError):  # unknown family
        LinkFit("broadcast", 1e-6, 1e-10)
    with pytest.raises(ValueError):
        calibrate.collective_family("carrier-pigeon")


def test_load_profile_for_accepts_comms_only_artifacts(tmp_path):
    """A model-less calibration prices any model's collectives; any other
    key field mismatch still rejects."""
    import jax

    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh()
    live = calibrate.profile_key(mesh)
    path = str(tmp_path / "cal.json")

    ok = dataclasses.replace(_profile(), key=live)
    ok.save(path)
    assert calibrate.load_profile_for(path, mesh).key == live

    # model="none" artifact loads for a model-keyed system
    modeless = dataclasses.replace(
        _profile(), key=dataclasses.replace(live, model="none"))
    modeless.save(path)
    assert calibrate.load_profile_for(path, mesh).key.model == "none"

    stale = dataclasses.replace(
        _profile(), key=dataclasses.replace(live, jax_version="0.0.0-stale"))
    stale.save(path)
    with pytest.raises(ProfileKeyMismatch):
        calibrate.load_profile_for(path, mesh)
    del jax  # imported only to mirror the call site's environment


# ---------------------------------------------------------------------------
# profile-threaded pricing
# ---------------------------------------------------------------------------


def test_uncalibrated_profile_equals_static_defaults():
    """profile=None and profile=UNCALIBRATED price bit-for-bit the same."""
    kw = dict(workers=4, transport="sequenced", n_buckets=4, stacked=True)
    a = cm.exchange_time_s(1e6, 1e6, **kw)
    b = cm.exchange_time_s(1e6, 1e6, profile=UNCALIBRATED, **kw)
    assert a == b
    sa = cm.streamed_exchange_time_s(
        1e6, 1e6, workers=4, transport="sequenced",
        group_fractions=(0.5, 0.5), backprop_s=1e-3)
    sb = cm.streamed_exchange_time_s(
        1e6, 1e6, workers=4, transport="sequenced",
        group_fractions=(0.5, 0.5), backprop_s=1e-3, profile=UNCALIBRATED)
    assert sa == sb


def test_calibrated_profile_changes_pricing():
    slow = dataclasses.replace(
        _profile(),
        fits=(LinkFit("gather", 1e-3, 1e-6), LinkFit("psum", 1e-3, 1e-6)))
    base = cm.exchange_time_s(1e6, 1e6, workers=4, transport="sequenced")
    cal = cm.exchange_time_s(1e6, 1e6, workers=4, transport="sequenced",
                             profile=slow)
    assert cal.exchange_s > base.exchange_s
    # explicit arguments still win over the profile
    override = cm.exchange_time_s(
        1e6, 1e6, cm.NETWORKS["tpu-dcn-host"], workers=4,
        transport="sequenced", profile=slow,
        alpha_s=cm.COLLECTIVE_ALPHA_S)
    assert override == base


# ---------------------------------------------------------------------------
# bugfix 1: real worker count reaches the auto policy
# ---------------------------------------------------------------------------


def _skewed_plan():
    """3 buckets tiny/huge/tiny -> readiness fractions ~(.005, .99, .005).

    With near-uniform fractions the streamed-vs-stacked boundary is
    wire-independent (the timeline algebra cancels it); skewed fractions
    put weight on an interior dispatch group, which is where the per-worker
    gather wire enters the decision."""
    chunk = 4096
    sizes = (chunk, 200 * chunk, chunk)
    bounds = (0, sizes[0], sizes[0] + sizes[1], sum(sizes))
    layout = bucketing.BucketLayout(
        total=sum(sizes), boundaries=bounds, chunk=chunk)
    return scheduler.build_plan(layout)


def test_workers_flip_borderline_decision():
    """Regression (scheduler.py used to hardcode workers=2): an 8-worker
    sequenced exchange must flip a borderline decision P=2 gets wrong —
    gather wire grows with P, and at 8 workers the big interior group's
    wire is too large to justify serializing after backprop."""
    plan = _skewed_plan()
    m_bytes = 4.0 * plan.layout.total
    kw = dict(transport="sequenced", backprop_s=500e-6)
    two = scheduler.choose_schedule(plan, m_bytes, 100e6, workers=2, **kw)
    eight = scheduler.choose_schedule(plan, m_bytes, 100e6, workers=8, **kw)
    assert two.schedule == "stacked"
    assert eight.schedule == "streamed"


def test_resolve_schedule_threads_workers():
    cfg = ReducerConfig(kind="fft", schedule="auto", transport="sequenced",
                        bucket_bytes=1 << 20)
    n = 1 << 22
    _, d2 = scheduler.resolve_schedule(cfg, n, 4096, workers=2)
    _, d8 = scheduler.resolve_schedule(cfg, n, 4096, workers=8)
    default, _ = scheduler.resolve_schedule(cfg, n, 4096)
    # wire priced at the ACTUAL worker count: 8 gather targets cost more
    assert d8.stacked_step_s > d2.stacked_step_s
    assert d8.streamed_step_s > d2.streamed_step_s
    # workers=None keeps the documented DEFAULT_WORKERS assumption
    assert scheduler.DEFAULT_WORKERS == 2
    assert default == scheduler.resolve_schedule(cfg, n, 4096, workers=2)[0]
    # and make_reducer accepts/threads the same inputs
    assert callable(make_reducer(cfg, batch_tokens=4096, workers=8))


# ---------------------------------------------------------------------------
# bugfix 2: exposed + hidden == exchange, always
# ---------------------------------------------------------------------------


@settings(deadline=None, max_examples=60)
@given(
    backprop_us=st.floats(0.0, 1e5),
    payload_mbits=st.floats(0.001, 1e3),
    n_groups=st.integers(1, 32),
    workers=st.integers(2, 64),
)
def test_streamed_accounting_identity(backprop_us, payload_mbits,
                                      n_groups, workers):
    """Property (regression: the old clamp broke it when the timeline's
    exposed tail exceeded backprop_s): the exchange work splits EXACTLY
    into hidden + exposed, and hidden can never exceed the backward pass
    it hides behind."""
    fracs = tuple(1.0 / n_groups for _ in range(n_groups))
    p = cm.streamed_exchange_time_s(
        8e6, payload_mbits * 1e6, workers=workers, transport="sequenced",
        group_fractions=fracs, backprop_s=backprop_us * 1e-6)
    assert p.exposed_s + p.hidden_s == pytest.approx(p.exchange_s, rel=1e-12)
    assert 0.0 <= p.hidden_s <= backprop_us * 1e-6 + 1e-15
    assert p.exposed_s >= 0.0
    assert p.step_s >= backprop_us * 1e-6


def test_accounting_identity_in_saturated_regime():
    """The exact shape the old clamp broke: exchange far larger than the
    backward pass, so hidden saturates at backprop_s and exposed must be
    exchange - backprop, not the un-recomputed leftover."""
    p = cm.streamed_exchange_time_s(
        8e6, 1e9, workers=8, transport="sequenced",
        group_fractions=(0.25, 0.25, 0.25, 0.25), backprop_s=1e-6)
    assert p.exchange_s > 100 * 1e-6
    assert p.exposed_s + p.hidden_s == pytest.approx(p.exchange_s, rel=1e-12)
    assert p.hidden_s <= 1e-6 + 1e-18


# ---------------------------------------------------------------------------
# bugfix 3: psum decisions price the dense runtime wire
# ---------------------------------------------------------------------------


def test_runtime_psum_wire_is_dense_spectrum():
    n = 1 << 20
    sparse_bits = 1e6
    modeled = cm.transport_wire_bits("psum", sparse_bits, 8, mode="modeled")
    runtime = cm.transport_wire_bits("psum", sparse_bits, 8, mode="runtime",
                                     n_elems=n)
    assert modeled == sparse_bits  # sparse-allreduce endpoint
    # ring allreduce of BOTH dense f32 spectrum planes
    assert runtime == pytest.approx(
        2.0 * cm.dense_spectrum_bits(n) * 7 / 8)
    assert runtime > 10 * modeled
    with pytest.raises(ValueError):  # runtime psum needs the buffer size
        cm.transport_wire_bits("psum", sparse_bits, 8, mode="runtime")
    with pytest.raises(ValueError):
        cm.transport_wire_bits("psum", sparse_bits, 8, mode="telepathy")
    # gather transports move the same bytes in both modes
    for t in ("allgather", "sequenced"):
        assert cm.transport_wire_bits(t, sparse_bits, 8, mode="runtime",
                                      n_elems=n) \
            == cm.transport_wire_bits(t, sparse_bits, 8, mode="modeled")


def test_choose_schedule_prices_psum_at_runtime_wire():
    """Regression: the auto policy used to price psum at the O(k) sparse
    endpoint; the runtime collective moves the dense dequantized spectrum,
    which choose_schedule (wire_mode='runtime' default) must bill."""
    layout = bucketing.build_layout(1 << 20, 1 << 18)
    plan = scheduler.build_plan(layout)
    kw = dict(workers=8, transport="psum", backprop_s=1e-3)
    runtime = scheduler.choose_schedule(plan, 4.0 * (1 << 20), 1e6, **kw)
    modeled = scheduler.choose_schedule(plan, 4.0 * (1 << 20), 1e6,
                                        wire_mode="modeled", **kw)
    assert runtime.stacked_step_s > modeled.stacked_step_s
    assert runtime.streamed_step_s > modeled.streamed_step_s


# ---------------------------------------------------------------------------
# the profiling pass on a live (fake-device) mesh
# ---------------------------------------------------------------------------


def test_calibrate_on_live_mesh():
    out = run_with_devices(
        """
import json
from repro.comms import calibrate
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh()
profile = calibrate.calibrate(
    mesh, "data", sizes_bytes=(1 << 12, 1 << 14, 1 << 16), iters=2,
    measure_stages=False)
d = profile.to_dict()
path = "/tmp/test_calibrate_artifact.json"
profile.save(path)
reloaded = calibrate.CostProfile.load(path, expect=profile.key)
assert reloaded == profile
assert calibrate.load_profile_for(path, mesh) == profile
print(json.dumps({
    "mesh": d["key"]["mesh"],
    "calibrated": d["calibrated"],
    "alphas": [f["alpha_s"] for f in d["fits"]],
    "betas": [f["beta_s_per_byte"] for f in d["fits"]],
}))
""",
        devices=2,
    )
    got = json.loads(out.strip().splitlines()[-1])
    assert got["mesh"] == [["data", 2]]
    assert got["calibrated"] is True
    assert all(a > 0 for a in got["alphas"])
    assert all(b > 0 for b in got["betas"])


# ---------------------------------------------------------------------------
# topology-keyed artifacts + per-axis fits (DESIGN.md §18)
# ---------------------------------------------------------------------------


def _two_level_profile():
    """Base fits plus per-axis (node/local) extras, as a 2-D calibration
    records them."""
    return CostProfile(
        key=ProfileKey(platform="cpu", mesh=(("node", 2), ("local", 4)),
                       model="none", jax_version="0.0.0",
                       axes=("node", "local")),
        fits=(LinkFit("gather", 25e-6, 1e-10, n_points=5),
              LinkFit("psum", 12e-6, 2e-10, n_points=5),
              LinkFit("gather", 80e-6, 9e-10, n_points=5, axis="node"),
              LinkFit("psum", 60e-6, 8e-10, n_points=5, axis="node"),
              LinkFit("gather", 5e-6, 3e-11, n_points=5, axis="local"),
              LinkFit("psum", 4e-6, 2e-11, n_points=5, axis="local")),
        throughputs=cm.TPU_V5E,
        backprop_flops_per_s=3.2e12,
    )


def test_per_axis_fit_accessors():
    prof = _two_level_profile()
    # named axis -> the per-axis fit; unknown or omitted axis -> base fit
    assert prof.fit_for("psum", axis="node").alpha_s == 60e-6
    assert prof.fit_for("psum", axis="local").alpha_s == 4e-6
    assert prof.fit_for("psum").alpha_s == 12e-6
    assert prof.fit_for("psum", axis="dcn9000").alpha_s == 12e-6
    assert prof.t_comm("allgather", axis="node") == pytest.approx(1.0 / 9e-10)
    assert prof.alpha_s("hierarchical", axis="local") == 5e-6  # gather family
    # round-trips with the axis field intact
    assert CostProfile.from_dict(prof.to_dict()) == prof


def test_per_axis_profile_validation():
    prof = _two_level_profile()
    with pytest.raises(ValueError):  # duplicate (family, axis)
        dataclasses.replace(prof, fits=prof.fits + (
            LinkFit("psum", 1e-6, 1e-10, axis="node"),))
    with pytest.raises(ValueError):  # per-axis fits alone: no base psum fit
        dataclasses.replace(prof, fits=(
            LinkFit("gather", 25e-6, 1e-10),
            LinkFit("psum", 1e-6, 1e-10, axis="node")))


def test_per_axis_fits_price_two_level_exchange():
    """two_level_exchange_time_s resolves the intra hop from the psum fit on
    'local' and the inter hop from the gather fit on 'node' — asymmetric
    per-axis rates must surface as intra/inter time asymmetry."""
    prof = _two_level_profile()
    plan = cm.two_level_exchange_time_s(
        4e6, 1e6, nodes=2, local=4, profile=prof)
    # same wire volumes priced at a flat profile (base fits only) for contrast
    flat_prof = dataclasses.replace(
        _two_level_profile(), fits=_two_level_profile().fits[:2])
    flat_plan = cm.two_level_exchange_time_s(
        4e6, 1e6, nodes=2, local=4, profile=flat_prof)
    assert plan.wire == flat_plan.wire
    # the fabric ('node') gather fit is ~9x slower than the base gather fit
    assert plan.inter_s > flat_plan.inter_s
    # the island ('local') psum fit is ~7x faster than the base psum fit
    assert plan.intra_s < flat_plan.intra_s


def test_transposed_topology_profile_rejected(tmp_path):
    """Bugfix (ISSUE 8 ride-along): the artifact key carries axis NAMES and
    sizes plus the calibrated exchange axes, so a (node=2, local=4)
    calibration is rejected on a (node=4, local=2) mesh instead of silently
    mispricing both hops."""
    out = run_with_devices("""
import dataclasses
from repro.comms import calibrate
from repro.comms.calibrate import ProfileKeyMismatch
from repro.launch.mesh import make_local_mesh

mesh_24 = make_local_mesh((2, 4))
mesh_42 = make_local_mesh((4, 2))
profile = calibrate.calibrate(
    mesh_24, ("node", "local"), sizes_bytes=(1 << 12, 1 << 14), iters=1,
    measure_stages=False)
assert profile.key.mesh == (("node", 2), ("local", 4))
assert profile.key.axes == ("node", "local")
# per-axis fits recorded for both exchange axes, plus the combined base fits
axes_seen = {f.axis for f in profile.fits}
assert axes_seen == {None, "node", "local"}, axes_seen

path = "/tmp/test_topo_profile.json"
profile.save(path)
assert calibrate.load_profile_for(path, mesh_24) == profile
try:
    calibrate.load_profile_for(path, mesh_42)
except ProfileKeyMismatch:
    pass
else:
    raise AssertionError("(2,4) artifact must be rejected on a (4,2) mesh")
# an axes-spec mismatch is also a rejection: the artifact calibrated the
# two-level pair, not a flat 'data' exchange
try:
    calibrate.load_profile_for(path, mesh_24, axes=("data",))
except ProfileKeyMismatch:
    pass
else:
    raise AssertionError("axes mismatch must be rejected")
print("TOPO_KEY_OK")
""")
    assert "TOPO_KEY_OK" in out


def test_v1_artifact_version_rejected(tmp_path):
    """Per-axis fits + topology-keyed meshes bumped ARTIFACT_VERSION to 2;
    v1 artifacts predate both and must be re-calibrated, not reinterpreted."""
    import json as _json

    path = str(tmp_path / "cal.json")
    d = _profile().to_dict()
    d["version"] = 1
    with open(path, "w") as f:
        _json.dump(d, f)
    with pytest.raises(ProfileKeyMismatch):
        CostProfile.load(path)
