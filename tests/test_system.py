"""End-to-end system behaviour: the paper's claim on the full stack —
compressed training tracks dense training (paper Fig. 11/12), on both the
transformer substrate and the paper-era convnet."""

import jax
import jax.numpy as jnp
import numpy as np

from helpers import run_with_devices
from repro.models.convnet import ConvConfig, ConvNet, synthetic_image_batch


def test_compressed_dp_training_tracks_dense():
    """4 fake devices, tiny LM: fft-compressed gradient exchange (theta=0.5)
    reaches within 15% of the dense-allreduce loss after 40 steps."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.configs.base import ArchConfig
from repro.comms.reducers import ReducerConfig
from repro.data import SyntheticConfig, SyntheticStream
from repro.models.transformer import LM
from repro.optim import OptConfig
from repro.train import TrainLoopConfig, init_state, train_loop
from repro.train.step import StepConfig

TINY = ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=64, remat="none")
from repro.jaxcompat import make_auto_mesh, set_mesh
mesh = make_auto_mesh((4,), ("data",))
model = LM(TINY)
opt = OptConfig(kind="adamw", lr=3e-3)
stream = SyntheticStream(SyntheticConfig(vocab_size=64, seq_len=32, global_batch=8))

def run(step_cfg):
    state = init_state(jax.random.PRNGKey(0), model, opt)
    with set_mesh(mesh):
        out = train_loop(model, opt, step_cfg, mesh, state, stream,
                         TrainLoopConfig(total_steps=40, log_every=39))
    return out["history"][-1]["loss"]

dense = run(StepConfig(mode="pjit"))
comp = run(StepConfig(mode="compressed_dp",
                      reducer=ReducerConfig(kind="fft", axis="data", theta=0.5)))
print("LOSSES", dense, comp)
assert comp < dense * 1.15 + 0.05, (dense, comp)
""", devices=4, timeout=560)
    assert "LOSSES" in out


def test_convnet_trains_with_compression():
    """Paper-family model (conv ResNet): compressed grads still learn."""
    import jax.flatten_util

    from repro.core.compressor import FFTCompressor, FFTCompressorConfig
    from repro.optim import OptConfig, apply_updates, init_opt_state

    cfg = ConvConfig(widths=(8, 16), blocks_per_stage=1, img_size=16)
    net = ConvNet(cfg)
    params = net.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(kind="sgd", lr=0.1, momentum=0.9)
    opt = init_opt_state(opt_cfg, params)
    comp = FFTCompressor(FFTCompressorConfig(theta=0.5, chunk=1024))

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(net.loss, has_aux=True)(params, batch)
        flat, unravel = jax.flatten_util.ravel_pytree(grads)
        flat_hat = comp.decompress(comp.compress(flat))
        grads = unravel(flat_hat)
        params, opt = apply_updates(opt_cfg, params, grads, opt)
        return params, opt, loss, metrics["acc"]

    accs = []
    loss = jnp.inf
    for i in range(100):
        batch = synthetic_image_batch(jax.random.PRNGKey(i), cfg, 32)
        params, opt, loss, acc = step(params, opt, batch)
        accs.append(float(acc))
    assert np.mean(accs[-10:]) > 0.7, np.mean(accs[-10:])
    assert np.isfinite(float(loss))
