"""Theorem 3.4/3.5 helpers, theta schedules, cost model (Fig. 9), HLO parse."""

import math

import pytest

from repro.analysis import hlo
from repro.comms import cost_model as cm
from repro.core import schedules, theory


def test_thm34_bound_structure():
    t = theory.thm34_bound(f0_minus_fstar=2.0, lipschitz=1.0, eta=0.1,
                           theta=0.7, sigma_sq=1.0, batch=32, steps=100)
    assert t.bound == pytest.approx(t.opt_term + t.noise_term)
    # noise term grows with theta^2 (the paper's accuracy-drop mechanism)
    t2 = theory.thm34_bound(2.0, 1.0, 0.1, 0.9, 1.0, 32, 100)
    assert t2.noise_term > t.noise_term
    # and shrinks with batch (Thm 3.4: increase b to tighten)
    t3 = theory.thm34_bound(2.0, 1.0, 0.1, 0.7, 1.0, 128, 100)
    assert t3.noise_term < t.noise_term


def test_thm35_schedule_diminishes_with_lr():
    eta = lambda s: 0.5 / math.sqrt(s + 1)
    sched = schedules.thm35_schedule(lipschitz=1.0, eta_schedule=eta)
    vals = [sched(s) for s in (0, 10, 100, 10_000)]
    assert all(v <= 0.5 for v in vals)  # lemma admissibility
    assert vals[0] > vals[1] > vals[2] > vals[3]
    # theta_t^2 == L * eta_t once below the clip
    assert vals[3] == pytest.approx(math.sqrt(eta(10_000)), rel=1e-6)


def test_step_and_poly_schedules():
    mixed = schedules.step_decay([(0, 0.99), (100, 0.0)])  # paper "mixed comp"
    assert mixed(50) == 0.99 and mixed(100) == 0.0
    poly = schedules.polynomial_decay(0.9, 100)
    assert poly(0) == pytest.approx(0.9) and poly(100) == 0.0
    sig = schedules.sigmoid_decay(0.9, midpoint=50, steepness=0.2)
    assert sig(0) > 0.8 and sig(200) < 0.2


def test_quantize_theta_bounds_recompiles():
    grid = {schedules.quantize_theta(t / 1000) for t in range(1000)}
    assert len(grid) <= 21  # bounded distinct compiled steps


def test_make_schedule_from_declarative_descriptions():
    assert schedules.make_schedule(None) is None
    const = schedules.make_schedule("constant", theta=0.7)
    assert const(0) == const(999) == 0.7
    mixed = schedules.make_schedule("step_decay", points=[[0, 0.99], [30, 0.0]])
    assert mixed(29) == 0.99 and mixed(30) == 0.0
    poly = schedules.make_schedule("polynomial_decay", theta0=0.9, total_steps=10)
    assert poly(10) == 0.0
    sig = schedules.make_schedule("sigmoid_decay", theta0=0.8, midpoint=5)
    assert 0.0 < sig(5) < 0.8
    t35 = schedules.make_schedule("thm35", lipschitz=1.0, eta=0.09)
    assert t35(0) == pytest.approx(0.3)
    with pytest.raises(ValueError):
        schedules.make_schedule("cosine", theta=0.5)


def test_schedule_curve_reports_realized_quantized_thetas():
    sched = schedules.make_schedule("step_decay", points=[[0, 0.99], [3, 0.0]])
    curve = schedules.schedule_curve(sched, 5)
    # 0.99 snaps to the 0.95 cap — the curve reports what actually RAN
    assert curve == (0.95, 0.95, 0.95, 0.0, 0.0)
    assert schedules.schedule_curve(None, 3) == (0.0, 0.0, 0.0)


# --- measured-curve helpers (convergence lab) -------------------------------


def test_estimate_curve_constants_descent_lemma():
    eta = 0.1
    # loss falls exactly eta*(1 - L*eta/2)*gsq per step for L=2: L-hat == 2
    gsq = [1.0, 1.0, 1.0]
    drop = eta * (1 - 2 * eta / 2) * 1.0
    loss = [2.0, 2.0 - drop, 2.0 - 2 * drop]
    c = theory.estimate_curve_constants(loss, gsq, eta=eta, batch=4, fstar=0.5)
    assert c.lipschitz == pytest.approx(2.0, rel=1e-6)
    assert c.f0_minus_fstar == pytest.approx(1.5)
    assert c.sigma_sq == pytest.approx(4 * 1.0)  # b * tail mean
    with pytest.raises(ValueError):
        theory.estimate_curve_constants([1.0], [1.0], 0.1, 4)


def test_thm34_envelope_holds_and_detects_violations():
    c = theory.CurveConstants(f0_minus_fstar=2.0, lipschitz=1.0, sigma_sq=4.0)
    gsq = [4.0, 2.0, 1.0, 0.5]
    env = theory.thm34_envelope(gsq, c, eta=0.1, theta=0.7, batch=8)
    assert env.holds
    assert env.min_so_far == (4.0, 2.0, 1.0, 0.5)
    assert all(b > 0 for b in env.bounds)
    # a curve whose grad energy NEVER decreases below the noise floor while
    # K grows must eventually violate the shrinking opt term
    flat = [1e4] * 200
    env_bad = theory.thm34_envelope(flat, c, eta=0.1, theta=0.0, batch=8)
    assert not env_bad.holds


def test_curves_close_pointwise():
    ok, div = theory.curves_close([1.0, 2.0, 3.0], [1.0, 2.0, 3.0])
    assert ok and div == 0.0
    ok, div = theory.curves_close([1.0, 2.0], [1.0, 2.1], atol=1e-2)
    assert not ok and div == pytest.approx(0.1)
    with pytest.raises(ValueError):
        theory.curves_close([1.0], [1.0, 2.0])


def test_assumption31_holds_stats_norm_tolerance():
    # quantization can push the reconstruction norm slightly above 1
    assert theory.assumption31_holds_stats(0.3, 1.02, theta=0.5, norm_tol=0.05)
    assert not theory.assumption31_holds_stats(0.3, 1.02, theta=0.5)
    assert not theory.assumption31_holds_stats(0.6, 0.9, theta=0.5)
    assert theory.assumption31_holds_stats(0.6, 0.9, theta=0.5, slack=1.5)


# --- §III-D cost model (Fig. 9) --------------------------------------------


def test_kmin_monotone_in_bandwidth():
    ks = [cm.k_min(bw, cm.TPU_V5E)
          for bw in (1e9, 6e9, 12.5e9, 50e9)]
    assert ks[0] < ks[1] < ks[2]  # faster network -> higher k needed
    # paper insight: easier to win on slow networks
    assert ks[0] < 1.5


def test_kmin_infinite_when_network_outruns_compressor():
    slow = cm.Throughputs(t_m=1e9, t_f=1e9, t_p=1e9, t_s=1e9)
    assert cm.k_min(50e9, slow) == float("inf")


def test_is_beneficial_consistent_with_kmin():
    thr = cm.TPU_V5E
    bw = 6e9
    k_star = cm.k_min(bw, thr)
    assert not cm.is_beneficial(1e8, bw, k_star * 0.9, thr)
    assert cm.is_beneficial(1e8, bw, k_star * 1.5, thr)


# --- HLO collective parsing -------------------------------------------------

SAMPLE_HLO = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.1 = bf16[64,512]{1,0} all-gather(bf16[16,512]{1,0} %y), replica_groups=[4,16]<=[64], dimensions={0}
  %rs = f32[32]{0} reduce-scatter(f32[256]{0} %z), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %cp = f32[1024]{0} collective-permute(f32[1024]{0} %w), source_target_pairs={{0,1},{1,0}}
  %a2a = (f32[16,16]{1,0}) all-to-all(f32[16,16]{1,0} %v), replica_groups={{0,1}}
"""


def test_parse_collectives_kinds_and_bytes():
    stats = hlo.parse_collectives(SAMPLE_HLO)
    assert stats["all-reduce"].count == 1
    assert stats["all-reduce"].raw_bytes == 128 * 256 * 4
    # ring model: 2 * bytes * (n-1)/n with n=4
    assert stats["all-reduce"].link_bytes == pytest.approx(
        2 * 128 * 256 * 4 * 3 / 4)
    assert stats["all-gather"].count == 1
    assert stats["all-gather"].raw_bytes == 64 * 512 * 2
    # iota groups [4,16]: group size 16
    assert stats["all-gather"].link_bytes == pytest.approx(
        64 * 512 * 2 * 15 / 16)
    assert stats["reduce-scatter"].link_bytes == pytest.approx(32 * 4 * 7)
    assert stats["collective-permute"].link_bytes == 1024 * 4
    assert stats["all-to-all"].count == 1
