"""Bucket layout invariants + the transport cost-model acceptance bound."""

import jax.numpy as jnp
import numpy as np
import pytest

from helpers import given, settings, st

from repro.comms import bucketing, cost_model as cm
from repro.comms.transport import TRANSPORT_NAMES, get_transport
from repro.core.compressor import FFTCompressor, FFTCompressorConfig

CHUNK = 4096


@settings(max_examples=25, deadline=None)
@given(
    total=st.integers(1, 40 * CHUNK + 137),
    bucket_chunks=st.integers(1, 8),
)
def test_layout_partitions_exactly(total, bucket_chunks):
    layout = bucketing.build_layout(total, bucket_chunks * CHUNK * 4, CHUNK)
    b = layout.boundaries
    assert b[0] == 0 and b[-1] == total
    assert all(lo < hi for lo, hi in zip(b, b[1:]))
    assert all(x % CHUNK == 0 for x in b[1:-1])
    assert sum(layout.sizes()) == total
    # deterministic: same inputs -> same layout
    assert layout == bucketing.build_layout(total, bucket_chunks * CHUNK * 4, CHUNK)


def test_layout_monolithic_when_unset_or_large():
    for bucket_bytes in (None, 10**12):
        layout = bucketing.build_layout(3 * CHUNK + 5, bucket_bytes, CHUNK)
        assert layout.n_buckets == 1
        assert layout.boundaries == (0, 3 * CHUNK + 5)


def test_layout_no_sub_chunk_tail_bucket():
    # tail shorter than a chunk rides the previous bucket
    total = 2 * CHUNK + 7
    layout = bucketing.build_layout(total, CHUNK * 4, CHUNK)
    assert layout.sizes()[-1] >= CHUNK or layout.n_buckets == 1
    assert sum(layout.sizes()) == total


def test_split_concat_roundtrip_with_ragged_tail():
    total = 5 * CHUNK + 321
    x = jnp.arange(total, dtype=jnp.float32)
    layout = bucketing.build_layout(total, 2 * CHUNK * 4, CHUNK)
    parts = bucketing.split_buckets(x, layout)
    assert [int(p.shape[0]) for p in parts] == list(layout.sizes())
    back = bucketing.concat_buckets(parts, layout)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_split_rejects_wrong_length():
    layout = bucketing.build_layout(CHUNK, None, CHUNK)
    with pytest.raises(ValueError):
        bucketing.split_buckets(jnp.zeros(CHUNK + 1), layout)


def test_residual_slices_partition_the_flat_space():
    """Per-bucket residual slices are exactly the gradient's bucket bounds."""
    params = {"w": jnp.zeros((3, CHUNK)), "b": jnp.zeros((17,))}
    n = bucketing.residual_size(params)
    assert n == 3 * CHUNK + 17
    layout = bucketing.build_layout(n, CHUNK * 4, CHUNK)
    covered = []
    for i in range(layout.n_buckets):
        lo, hi = layout.bounds(i)
        covered.extend(range(lo, hi))
    assert covered == list(range(n))


def test_reducer_config_accepts_bucket_bytes_and_transport():
    from repro.comms import ReducerConfig, make_reducer

    cfg = ReducerConfig(kind="fft", axis="data", bucket_bytes=1 << 20,
                        transport="psum")
    assert cfg.layout_for(1 << 20).n_buckets == 4
    assert callable(make_reducer(cfg))
    with pytest.raises(ValueError):
        ReducerConfig(kind="fft", transport="carrier-pigeon")
    with pytest.raises(ValueError):
        ReducerConfig(kind="fft", bucket_bytes=0)


def test_transport_registry():
    for name in TRANSPORT_NAMES:
        assert get_transport(name).name == name
    with pytest.raises(ValueError):
        get_transport("nope")


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_psum_wire_at_most_one_over_p_of_allgather():
    """Acceptance bound: at equal theta, the spectrum-psum transport's
    per-worker wire bits are <= 1/P of the all-gather transport's."""
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    n = 1 << 24
    payload_bits = comp.wire_bits(n)
    for workers in (2, 4, 8, 64, 256):
        ag = cm.transport_wire_bits("allgather", payload_bits, workers)
        ps = cm.transport_wire_bits("psum", payload_bits, workers)
        assert ps <= ag / workers, (workers, ps, ag)


def test_sequenced_ships_allgather_volume():
    assert cm.transport_wire_bits("sequenced", 1000, 8) == cm.transport_wire_bits(
        "allgather", 1000, 8
    )


def test_bucket_count_and_overlap():
    assert cm.bucket_count(64 << 20, None) == 1
    assert cm.bucket_count(64 << 20, 4 << 20) == 16
    assert cm.overlap_fraction(1) == 0.0
    assert cm.overlap_fraction(16) == pytest.approx(15 / 16)


def test_pipelined_exchange_never_slower_than_monolithic():
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    m_bytes = 64 << 20
    payload_bits = comp.wire_bits(m_bytes // 4)
    for transport in ("sequenced", "psum"):
        for n_buckets in (2, 4, 16):
            mono = cm.exchange_time_s(
                m_bytes, payload_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E,
                workers=8, transport=transport, n_buckets=1)
            piped = cm.exchange_time_s(
                m_bytes, payload_bits, cm.NETWORKS["tpu-dcn-host"], cm.TPU_V5E,
                workers=8, transport=transport, n_buckets=n_buckets)
            assert piped.exchange_s <= mono.exchange_s + 1e-12
            assert piped.overlap > 0.0


def test_psum_exchange_faster_than_allgather_at_scale():
    """The O(k) wire term makes psum win once P is large enough."""
    comp = FFTCompressor(FFTCompressorConfig(theta=0.7))
    m_bytes = 64 << 20
    payload_bits = comp.wire_bits(m_bytes // 4)
    t = cm.NETWORKS["tpu-dcn-host"]
    ag = cm.exchange_time_s(m_bytes, payload_bits, t, cm.TPU_V5E,
                            workers=64, transport="allgather", n_buckets=1)
    ps = cm.exchange_time_s(m_bytes, payload_bits, t, cm.TPU_V5E,
                            workers=64, transport="psum", n_buckets=1)
    assert ps.exchange_s < ag.exchange_s
