"""Sparsification (freq + time domain) and packing — incl. the Assumption 3.1
property the convergence theory rests on (paper §III-C)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import given, settings, st  # hypothesis or deterministic fallback

from repro.core import fft as cfft
from repro.core import packing, sparsify, theory
from repro.core.compressor import FFTCompressor, FFTCompressorConfig, TimeDomainCompressor


def test_fft_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (10000,))
    freqs, n = cfft.chunked_rfft(x)
    xr = cfft.chunked_irfft(freqs, n)
    np.testing.assert_allclose(np.array(x), np.array(xr), atol=1e-4)


def test_parseval_energy_accounting():
    x = jax.random.normal(jax.random.PRNGKey(1), (4096,))
    freqs, _ = cfft.chunked_rfft(x)
    e_time = float(jnp.sum(x * x))
    e_freq = float(jnp.sum(cfft.chunk_energy(freqs)))
    assert e_freq == pytest.approx(e_time, rel=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), theta=st.sampled_from([0.3, 0.5, 0.7, 0.9]))
def test_assumption31_sqrt_theta_bound(seed, theta):
    """PROVABLE bound: dropping the theta-fraction smallest-|.| coefficients
    discards <= theta of the energy => ||v - v_hat|| <= sqrt(theta)||v||.
    Holds for ANY input, any theta (DESIGN.md §6)."""
    v = jax.random.normal(jax.random.PRNGKey(seed), (8192,)) * jax.random.uniform(
        jax.random.PRNGKey(seed + 1), (8192,)
    )
    sparse, _, n = sparsify.frequency_sparsify(v, theta)
    v_hat = cfft.chunked_irfft(sparse, n)
    err, norm_ratio = theory.assumption31_stats(v, v_hat)
    assert float(err) <= theta**0.5 + 1e-3
    assert float(norm_ratio) <= 1.0 + 1e-4


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_assumption31_linear_theta_on_gaussian(seed):
    """On gaussian gradients (paper Fig. 3: the empirical case) the error is
    far below the literal theta bound of Assumption 3.1."""
    v = jax.random.normal(jax.random.PRNGKey(seed), (16384,)) * 0.05
    for theta in (0.5, 0.7):
        sparse, _, n = sparsify.frequency_sparsify(v, theta)
        v_hat = cfft.chunked_irfft(sparse, n)
        assert theory.assumption31_holds(v, v_hat, theta)


def test_fft_preserves_signs_better_than_time_domain():
    """Paper Fig. 7: frequency-domain sparsification preserves the sign of
    dropped entries; time-domain zeroing does not."""
    g = jax.random.normal(jax.random.PRNGKey(2), (65536,)) * 0.05
    cfg = FFTCompressorConfig(theta=0.7, quantize=False)
    fft_hat = FFTCompressor(cfg).decompress(FFTCompressor(cfg).compress(g))
    time_hat = TimeDomainCompressor(cfg).decompress(TimeDomainCompressor(cfg).compress(g))
    sign_fft = float(jnp.mean(jnp.sign(fft_hat) == jnp.sign(g)))
    sign_time = float(jnp.mean(jnp.sign(time_hat) == jnp.sign(g)))
    assert sign_fft > 0.75
    assert sign_fft > sign_time + 0.3  # paper's qualitative claim, quantified


def test_topk_mask_exact():
    mag = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (4, 128)))
    mask = sparsify.topk_mask(mag, 32)
    assert mask.sum(-1).tolist() == [32] * 4
    thresh = jnp.sort(mag, axis=-1)[:, -32]
    assert bool(jnp.all(mag[mask].reshape(4, 32) >= thresh[:, None] - 1e-7))


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([8, 32, 96]))
def test_index_pack_roundtrip(seed, k):
    x = jax.random.normal(jax.random.PRNGKey(seed), (3, 128))
    idx = sparsify.topk_select(jnp.abs(x), k)
    vals = packing.pack_by_indices(x, idx)
    dense = packing.unpack_by_indices(vals, idx, 128)
    mask = sparsify.topk_mask(jnp.abs(x), k)
    np.testing.assert_allclose(np.array(dense), np.array(x * mask), atol=1e-7)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), theta=st.sampled_from([0.5, 0.75]))
def test_bitmap_pack_roundtrip(seed, theta):
    """Paper's status-bitmap + prefix-sum pack (parallel pack algorithm)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 256))
    k = sparsify.keep_count(256, theta)
    mask = sparsify.topk_mask(jnp.abs(x), k)
    payload = packing.pack_bitmap(x, mask, k)
    dense = packing.unpack_bitmap(payload, 256)
    np.testing.assert_allclose(np.array(dense), np.array(x * mask), atol=1e-7)


def test_bitmap_word_layout():
    mask = jnp.zeros((1, 64), bool).at[0, 0].set(True).at[0, 33].set(True)
    words = packing.make_bitmap(mask)
    assert words.shape == (1, 2)
    assert int(words[0, 0]) == 1 and int(words[0, 1]) == 2
    back = packing.bitmap_to_mask(words, 64)
    assert bool(jnp.all(back == mask))


def test_payload_size_accounting():
    # bitmap beats the index layout below theta = 15/16 (16-bit indices)
    n, bits = 4096, 8
    for theta, bitmap_smaller in [(0.7, True), (0.98, False)]:
        k = sparsify.keep_count(n, theta)
        idx_bits = packing.payload_bits_index(n, k, bits)
        bm_bits = packing.payload_bits_bitmap(n, k, bits)
        assert (bm_bits < idx_bits) == bitmap_smaller, (theta, bm_bits, idx_bits)
